"""The zero-overhead-off guarantee, measured and asserted.

With ``ServeConfig.obs`` falsy (the default) the engine's observer is
the shared ``NullObserver``: every hook one attribute load plus an empty
call, ``clock()`` returning 0.0 without a syscall.  This bench proves
that costs nothing in the only currency that matters — per decoded
token:

  1. MICRO: ns/call of the NULL hooks, measured directly over 1e6
     calls; multiplied by a conservative hooks-per-token budget
     (``HOOKS_PER_TOKEN``, > the engine's actual per-token hook count)
     it must stay under ``MAX_OVERHEAD_FRAC`` of the measured per-token
     decode latency.  This assertion is DETERMINISTIC in what it
     compares (pure-python call cost vs a jitted forward step), so it
     gates without CPU-noise flakiness.
  2. A/B: interleaved off-vs-instrumented ``generate`` wall times over
     the same workload (median of alternating runs — interleaving
     cancels thermal/load drift), reported for the record, plus the
     cheap exactness check: greedy token streams IDENTICAL between the
     off and instrumented engines — observation must never perturb what
     it observes.

  PYTHONPATH=src python -m benchmarks.bench_obs_overhead
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import init_params
from repro.obs import NULL
from repro.serving import Engine, PagedCacheAdapter, ServeConfig

N_REQ = 8
MAX_NEW = 8
MAX_LEN = 64
BLOCK = 8
REPS = 3  # interleaved A/B pairs
#: conservative per-token hook budget — the engine's serving loop touches
#: the observer ≤ ~6 times per decoded token (step clock ×2, step_done,
#: queue_depth, and amortized submit/finish hooks); budget double that
HOOKS_PER_TOKEN = 12
MAX_OVERHEAD_FRAC = 0.01  # off-mode hooks must cost < 1% of a token


def _hook_ns(n: int = 1_000_000) -> float:
    """Measured ns per NULL hook call (attribute load + empty call)."""
    obs = NULL
    t0 = time.perf_counter()
    for _ in range(n):
        obs.step_done(0.0, 0.0, n_active=1, n_tokens=1)
        obs.clock()
    return (time.perf_counter() - t0) / (2 * n) * 1e9


def _engine(cfg, params, obs: bool) -> Engine:
    sc = ServeConfig(n_slots=N_REQ, max_len=MAX_LEN, obs=obs)
    return Engine(cfg, params, sc, cache=PagedCacheAdapter(
        block_size=BLOCK, n_blocks=N_REQ * MAX_LEN // BLOCK))


def _workload(vocab: int):
    rng = np.random.RandomState(0)
    return [rng.randint(0, vocab, size=(int(n),)).astype(np.int32)
            for n in rng.randint(4, 24, size=N_REQ)]


def run():
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        sliding_window=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _workload(cfg.vocab_size)

    # warm every jit cache once so A/B times measure the serving loop
    _engine(cfg, params, obs=False).generate(prompts, max_new_tokens=2)

    hook_ns = _hook_ns()

    # interleaved A/B over fresh engines (fresh pools, same jit caches)
    times = {False: [], True: []}
    outs = {}
    for _ in range(REPS):
        for obs_on in (False, True):
            eng = _engine(cfg, params, obs=obs_on)
            t0 = time.perf_counter()
            outs[obs_on] = eng.generate(prompts, max_new_tokens=MAX_NEW)
            times[obs_on].append(time.perf_counter() - t0)
    assert outs[False] == outs[True], (
        "instrumentation must not perturb the greedy token stream")

    n_tok = sum(len(o) for o in outs[False])
    off_s = float(np.median(times[False]))
    on_s = float(np.median(times[True]))
    tok_us = off_s / n_tok * 1e6
    overhead_frac = (hook_ns * HOOKS_PER_TOKEN) / (tok_us * 1e3)
    assert overhead_frac < MAX_OVERHEAD_FRAC, (
        f"off-mode hook cost {hook_ns:.0f} ns x {HOOKS_PER_TOKEN}/token = "
        f"{overhead_frac:.2%} of a {tok_us:.0f} us token — NullObserver is "
        f"no longer free; keep the hooks to shared no-op attributes")
    return dict(hook_ns=hook_ns, hooks_per_token=HOOKS_PER_TOKEN,
                tok_us=tok_us, overhead_frac=overhead_frac,
                off_tok_s=n_tok / off_s, on_tok_s=n_tok / on_s,
                off_s=off_s, on_s=on_s, n_tokens=n_tok)


def main():
    r = run()
    print(f"NULL hook: {r['hook_ns']:.0f} ns/call; "
          f"budget {r['hooks_per_token']} hooks/token = "
          f"{r['overhead_frac']:.4%} of a {r['tok_us']:.0f} us decode "
          f"token (< {MAX_OVERHEAD_FRAC:.0%} asserted)")
    print(f"interleaved A/B (median of {REPS}): off "
          f"{r['off_tok_s']:.1f} tok/s vs instrumented "
          f"{r['on_tok_s']:.1f} tok/s over {r['n_tokens']} tokens "
          f"(CPU, informational)")
    print("off/on greedy streams identical; off-mode overhead within "
          "noise OK")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    main()
