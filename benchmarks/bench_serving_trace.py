"""Trace replay: synchronous-submit Engine vs ScheduledEngine, same trace.

A bursty Poisson arrival process over mixed prompt/output lengths is
replayed — wall-clock — against BOTH serving paths:

  sync   the pre-scheduler ``Engine``: ``submit`` runs a whole-prompt,
         batch-of-1 prefill synchronously at admission.  Each prompt
         bucket (8/16/32) that first appears MID-SERVE pays its jit
         compile inside the replay, and every prefill freezes all
         in-flight decode streams for the full prompt.
  sched  ``ScheduledEngine``: ``submit`` only enqueues; ``step`` plans a
         token-budget iteration interleaving fixed-width prefill CHUNKS
         with the batched decode.  One static chunk shape ⇒ ONE compiled
         prefill program, warmed before the trace starts — no mid-serve
         compile stalls, no whole-prompt admission freeze.

Both engines replay the IDENTICAL trace (same prompts, same per-request
output budgets, same arrival offsets, FCFS admission) after an identical
one-request warm pass, and every greedy stream must come out
token-identical — chunked prefill writes bit-exact KV (the
``tests/test_sched.py`` grid), so the comparison is pure scheduling.

Reported per path: per-request TTFT (t_first − trace arrival) p50/p99,
aggregate tokens/s over the replay, deferral/preemption counters, and
(sched) iteration/chunk counts from the planner.  The payload persists to
``BENCH_serving_trace.json`` beside this module, with the PR 7
``BENCH_serving_obs.json`` headline attached as the prior-run baseline
for the perf trajectory.  The acceptance gate — scheduled p99 TTFT
strictly below synchronous p99 TTFT under the bursty trace — is asserted
in ``run()``.

  PYTHONPATH=src python -m benchmarks.bench_serving_trace
  SERVING_TRACE_FAST=1 ...            # reduced CI shape

CPU timings are illustrative for absolute numbers; the p99 ordering is
structural (the sync path's mid-serve bucket compiles and whole-prompt
admission stalls are simply not in the scheduled path's program set).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import init_params
from repro.serving import (Engine, PagedCacheAdapter, ServeConfig,
                           SchedConfig, ScheduledEngine)
from repro.serving.engine import Request

FAST = os.environ.get("SERVING_TRACE_FAST", "") == "1"

N_REQ = 8 if FAST else 16
MAX_NEW = 4 if FAST else 8          # per-request cap; outputs are mixed
MAX_LEN = 64
N_SLOTS = 8
BLOCK = 8
CHUNK = 8                           # one static chunk shape (= block)
BUDGET = 32                         # decode slots + chunks per iteration
MEAN_IAT_MS = 3.0                   # Poisson mean interarrival — bursty
SEED = 0


def build_trace():
    """(prompt, max_new, arrival_offset_s) triples — identical for both
    paths.  Prompt lengths span the 8/16/32 prefill buckets (the first
    three are pinned, one per bucket, so the sync path always meets every
    bucket mid-serve); output budgets are mixed; arrivals are Poisson."""
    rng = np.random.RandomState(SEED)
    vocab = 1 << 14  # clipped below to the real vocab
    lens = rng.randint(4, 31, size=N_REQ)
    lens[:3] = (6, 14, 28)  # one per bucket: 8, 16, 32
    prompts = [rng.randint(0, vocab, size=(int(n),)).astype(np.int32)
               for n in lens]
    outs = rng.randint(2, MAX_NEW + 1, size=N_REQ).tolist()
    offsets = np.cumsum(rng.exponential(MEAN_IAT_MS / 1e3, size=N_REQ))
    return prompts, outs, offsets.tolist()


def _make_engine(cfg, params, scheduled: bool):
    sc = ServeConfig(n_slots=N_SLOTS, max_len=MAX_LEN)
    cache = PagedCacheAdapter(block_size=BLOCK,
                              n_blocks=N_SLOTS * MAX_LEN // BLOCK)
    if scheduled:
        return ScheduledEngine(cfg, params, sc, cache=cache,
                               scfg=SchedConfig(token_budget=BUDGET,
                                                chunk_tokens=CHUNK))
    return Engine(cfg, params, sc, cache=cache)


def _outstanding(eng) -> bool:
    if isinstance(eng, ScheduledEngine):
        return bool(eng.waiting or eng.prefilling or eng.active
                    or eng.preempted)
    return bool(eng.active)


def replay(eng, prompts, outs, offsets):
    """Drive one engine through the trace in wall-clock time: submit each
    request when its arrival offset is due (FCFS; the sync engine's
    submit is retried while the pool defers it), stepping in between.
    Returns (requests, wall_seconds)."""
    reqs = [Request(prompt=p, max_new_tokens=o)
            for p, o in zip(prompts, outs)]
    t0 = time.perf_counter()
    for r, off in zip(reqs, offsets):
        r.t_arrival = t0 + off  # TTFT counts from the TRACE arrival
    queue: list = []  # arrived, not yet admitted (sync: pool deferred it)
    i = 0
    while i < len(reqs) or queue or _outstanding(eng):
        now = time.perf_counter()
        while i < len(reqs) and reqs[i].t_arrival <= now:
            queue.append(reqs[i])
            i += 1
        while queue and eng.submit(queue[0]):
            queue.pop(0)  # scheduled submit always enqueues; sync may defer
        if _outstanding(eng):
            eng.step()
        elif not queue and i < len(reqs):
            time.sleep(max(0.0, reqs[i].t_arrival - time.perf_counter()))
    return reqs, time.perf_counter() - t0


def _metrics(reqs, wall_s, eng) -> dict:
    ttft = np.array([r.t_first - r.t_arrival for r in reqs])
    n_tok = sum(len(r.out_tokens) for r in reqs)
    row = dict(ttft_p50_ms=1e3 * float(np.percentile(ttft, 50)),
               ttft_p99_ms=1e3 * float(np.percentile(ttft, 99)),
               ttft_max_ms=1e3 * float(ttft.max()),
               tok_s=n_tok / wall_s, wall_s=wall_s, n_tokens=n_tok,
               deferred=eng.stats["n_deferred"],
               preempted=eng.stats["n_preempted"],
               peak_streams=eng.stats["peak_active"])
    return row


def run():
    """Replay the trace on both paths; returns the persistable doc (and
    asserts the acceptance gate: identical greedy streams AND scheduled
    p99 TTFT strictly below synchronous p99 TTFT)."""
    cfg = reduce_config(get_config("mistral-7b")).with_(
        block_style="skipless", dtype="float32", param_dtype="float32",
        sliding_window=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    # O(1) logit streams so greedy argmax is well-conditioned
    params["embed"]["table"] = params["embed"]["table"] * 50.0

    prompts, outs, offsets = build_trace()
    prompts = [p % cfg.vocab_size for p in prompts]

    streams, rows = {}, {}
    for name, scheduled in (("sync", False), ("sched", True)):
        eng = _make_engine(cfg, params, scheduled)
        # IDENTICAL warm on both paths: decode program + the shortest
        # prompt's prefill (sync: bucket 8; sched: the one chunk program).
        # Buckets 16/32 stay COLD on purpose — first arriving mid-serve,
        # exactly what a static-shape chunk program never pays.
        eng.generate([prompts[0][:6]], max_new_tokens=2)
        reqs, wall_s = replay(eng, prompts, outs, offsets)
        streams[name] = [list(r.out_tokens) for r in reqs]
        rows[name] = _metrics(reqs, wall_s, eng)
        if scheduled:
            rows[name]["iterations"] = eng.stats.get("sched_iterations", 0)
            rows[name]["chunks"] = eng.stats.get("sched_chunks", 0)

    assert streams["sync"] == streams["sched"], (
        "greedy streams diverged between the synchronous and scheduled "
        "paths — chunked prefill must be token-exact")
    assert rows["sched"]["ttft_p99_ms"] < rows["sync"]["ttft_p99_ms"], (
        "scheduled engine must beat the synchronous engine on p99 TTFT "
        "under the bursty mixed-length trace: "
        f"sched {rows['sched']['ttft_p99_ms']:.1f} ms vs "
        f"sync {rows['sync']['ttft_p99_ms']:.1f} ms")

    doc = {
        "schema": "bench_serving_trace/v1",
        "workload": {
            "n_requests": N_REQ, "fast": FAST, "seed": SEED,
            "prompt_lens": [len(p) for p in prompts],
            "max_new": outs, "mean_interarrival_ms": MEAN_IAT_MS,
            "arrival_offsets_ms": [round(1e3 * o, 3) for o in offsets]},
        "engine": {
            "cache_kind": "paged", "n_slots": N_SLOTS, "max_len": MAX_LEN,
            "block_size": BLOCK, "chunk_tokens": CHUNK,
            "token_budget": BUDGET},
        "sync": rows["sync"],
        "sched": rows["sched"],
        "delta": {
            "ttft_p99_speedup": (rows["sync"]["ttft_p99_ms"]
                                 / rows["sched"]["ttft_p99_ms"]),
            "ttft_p50_speedup": (rows["sync"]["ttft_p50_ms"]
                                 / rows["sched"]["ttft_p50_ms"]),
            "tok_s_ratio": rows["sched"]["tok_s"] / rows["sync"]["tok_s"]},
        "identical_streams": True,
    }

    # prior-run baseline: PR 7's instrumented paged serve (different
    # workload — attached for the perf trajectory, not compared 1:1)
    obs_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_serving_obs.json")
    if os.path.exists(obs_path):
        with open(obs_path) as fh:
            h = json.load(fh).get("headline", {})
        doc["baseline_serving_obs"] = {
            "ttft_p50_ms": h.get("ttft_p50_ms"),
            "ttft_p99_ms": h.get("ttft_p99_ms"),
            "decode_step_p50_ms": h.get("decode_step_p50_ms"),
            "note": "PR 7 synchronous instrumented serve (its own "
                    "workload); this file's sync/sched rows share ONE "
                    "trace and are the like-for-like comparison"}
    return doc


def write_trace_doc(doc, path: str = "") -> str:
    """Persist the payload (default: benchmarks/BENCH_serving_trace.json
    next to this module) — the artifact CI uploads."""
    path = path or os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_serving_trace.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def main():
    doc = run()
    w = doc["workload"]
    print(f"trace: {w['n_requests']} requests, prompts "
          f"{min(w['prompt_lens'])}..{max(w['prompt_lens'])} tok "
          f"(buckets 8/16/32), outputs 2..{max(w['max_new'])} tok, "
          f"Poisson mean interarrival {w['mean_interarrival_ms']} ms"
          f"{' [FAST]' if w['fast'] else ''}")
    hdr = ("path", "ttft_p50_ms", "ttft_p99_ms", "tok_s", "wall_s",
           "deferred", "preempted", "peak_streams")
    print(" ".join(f"{h:>12}" for h in hdr))
    for name in ("sync", "sched"):
        r = doc[name]
        print(" ".join([f"{name:>12}"] + [
            f"{r[h]:>12.2f}" if isinstance(r[h], float) else f"{r[h]:>12}"
            for h in hdr[1:]]))
    d = doc["delta"]
    print(f"sched beats sync p99 TTFT {d['ttft_p99_speedup']:.1f}x "
          f"(p50 {d['ttft_p50_speedup']:.1f}x, tok/s ratio "
          f"{d['tok_s_ratio']:.2f}); all greedy streams token-identical")
    if "baseline_serving_obs" in doc:
        b = doc["baseline_serving_obs"]
        print(f"PR 7 obs baseline (own workload): TTFT p50/p99 "
              f"{b['ttft_p50_ms']:.1f}/{b['ttft_p99_ms']:.1f} ms")
    path = write_trace_doc(doc)
    print(f"BENCH_serving_trace.json written -> {path}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    main()
