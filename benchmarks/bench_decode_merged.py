"""Merged-weight decode fast path: measured tokens/s + HLO bytes/token.

The paper's §3 claim — removing Q and P cuts decode bandwidth — measured
on the serving hot path instead of the weight table.  Two CPU-runnable
views per arch (Mistral-7B is the paper's GQA example):

  * measured: a reduced Mistral-shaped ``skipless`` model vs its exact
    QP-merged rewrite, greedy-decoding through the jitted ``serve_step``;
    reports tokens/s for the generic vs merged fast path and checks the
    two streams agree token-for-token (the merge is exact).
  * compiled: the full Mistral-7B-shaped ``serve_step`` lowered on this
    backend; ``cost_analysis()`` bytes-accessed per decode step with and
    without the Q/P weights.  The scanned layer stack is counted once by
    XLA's cost model (same loop artifact both sides, see launch/dryrun),
    so the delta under-states the full-depth saving — the analytic
    full-depth weight stream (paper §3 model) is printed next to it.

Merged must access strictly fewer bytes: wq/wp are simply not in the
program.  The same comparison is made for the PREFILL program (the
stream-as-query fast path dispatched through the PrefillBackend
registry) — the TTFT side of the paper's claim.

  PYTHONPATH=src python -m benchmarks.bench_decode_merged
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import active_weights_per_token, merge_skipless
from repro.core.analysis import cost_dict
from repro.launch import steps as steps_lib
from repro.models import (DensePrefillDest, forward_prefill, forward_step,
                          init_params)


def _measured_tok_s(arch: str, n_new: int = 24):
    """Greedy-decode a reduced skipless model and its merged rewrite."""
    cfg = reduce_config(get_config(arch)).with_(
        block_style="skipless", dtype="float32", param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    # O(1) streams so the merged/unmerged logit comparison is well-conditioned
    params["embed"]["table"] = params["embed"]["table"] * 50.0
    mparams, mcfg = merge_skipless(params, cfg, "qp")

    B, S_pre = 4, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_pre), 0,
                              cfg.vocab_size)

    def make_step(step_cfg):
        @jax.jit
        def greedy_step(pp, t, cc):
            logits, cc = forward_step(pp, step_cfg, t, cc)
            return jnp.argmax(logits[:, :step_cfg.vocab_size], axis=-1), cc
        return greedy_step

    def decode_loop(step, p, c, last, reps: int = 3):
        # warm: compile + one real step outside the timed window; then
        # best-of-reps (CPU timing on these tiny shapes is noisy — the
        # TPU-relevant number is the compiled bytes/token below)
        jax.block_until_ready(step(p, last, c)[0])
        best = 0.0
        for _ in range(reps):
            tok, cc, out = last, c, []
            t0 = time.perf_counter()
            for _ in range(n_new):
                tok, cc = step(p, tok, cc)
                out.append(tok)
            jax.block_until_ready(out[-1])
            best = max(best, B * n_new / (time.perf_counter() - t0))
        return np.asarray(jnp.stack(out)), best

    lg0, c0 = forward_prefill(params, cfg, toks, DensePrefillDest(64))
    lg1, c1 = forward_prefill(mparams, mcfg, toks, DensePrefillDest(64))
    first0 = jnp.argmax(lg0[:, :cfg.vocab_size], axis=-1)
    first1 = jnp.argmax(lg1[:, :cfg.vocab_size], axis=-1)
    toks0, tok_s0 = decode_loop(make_step(cfg), params, c0, first0)
    toks1, tok_s1 = decode_loop(make_step(mcfg), mparams, c1, first1)
    assert np.array_equal(toks0, toks1), (
        "merged fast path must emit the unmerged model's greedy stream "
        "token-for-token (the merge is exact)")
    return dict(tok_s_skipless=tok_s0, tok_s_merged=tok_s1,
                tokens_equal=True)


def _compiled_bytes(cfg, batch: int = 1, cache_len: int = 1024):
    """bytes-accessed / flops of one jitted serve_step (lower+compile only)."""
    fn, _ = steps_lib.build_step(cfg, "decode")
    pshape = steps_lib.param_specs(cfg)
    cshape = steps_lib.cache_specs(cfg, batch, cache_len)
    token = jax.ShapeDtypeStruct((batch,), jnp.int32)
    compiled = jax.jit(fn).lower(pshape, token, cshape).compile()
    c = cost_dict(compiled)
    return float(c.get("bytes accessed", -1.0)), float(c.get("flops", -1.0))


def _compiled_prefill_bytes(cfg, batch: int = 1, seq_len: int = 256):
    """bytes-accessed of one jitted prefill (lower+compile only) — the
    TTFT-side twin of ``_compiled_bytes``.  Dispatches through the
    PrefillBackend registry, so ``skipless_merged`` lowers the stream-as-
    query fast path (no wq/wp reads anywhere in the prompt forward)."""
    fn, _ = steps_lib.build_step(cfg, "prefill")
    pshape = steps_lib.param_specs(cfg)
    batch_spec = {"inputs": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}
    compiled = jax.jit(fn).lower(pshape, batch_spec).compile()
    return float(cost_dict(compiled).get("bytes accessed", -1.0))


def run(arch: str = "mistral-7b"):
    full = get_config(arch)
    bytes_skipless, _ = _compiled_bytes(full.with_(block_style="skipless"))
    bytes_merged, _ = _compiled_bytes(full.with_(block_style="skipless_merged"))
    assert bytes_merged < bytes_skipless, (
        "merged decode must access strictly fewer HBM bytes "
        f"(no wq/wp reads): {bytes_merged} vs {bytes_skipless}")
    pf_skipless = _compiled_prefill_bytes(full.with_(block_style="skipless"))
    pf_merged = _compiled_prefill_bytes(
        full.with_(block_style="skipless_merged"))
    assert pf_merged < pf_skipless, (
        "merged prefill must access strictly fewer HBM bytes "
        f"(no wq/wp reads): {pf_merged} vs {pf_skipless}")
    meas = _measured_tok_s(arch)
    # analytic full-depth weight stream (paper §3 model, bf16 weights)
    w_with = active_weights_per_token(full, with_qp=True) * 2
    w_wo = active_weights_per_token(full, with_qp=False) * 2
    return [dict(arch=arch,
                 bytes_per_token_skipless=bytes_skipless,
                 bytes_per_token_merged=bytes_merged,
                 bytes_saved_frac=1.0 - bytes_merged / bytes_skipless,
                 prefill_bytes_skipless=pf_skipless,
                 prefill_bytes_merged=pf_merged,
                 prefill_bytes_saved_frac=1.0 - pf_merged / pf_skipless,
                 model_weight_bytes_with_qp=w_with,
                 model_weight_bytes_without_qp=w_wo,
                 model_bytes_saved_frac=1.0 - w_wo / w_with,
                 **meas)]


def main():
    rows = run()
    for r in rows:
        print(f"{r['arch']}: serve_step bytes/token "
              f"{r['bytes_per_token_skipless'] / 1e6:.1f} MB -> "
              f"{r['bytes_per_token_merged'] / 1e6:.1f} MB "
              f"({100 * r['bytes_saved_frac']:.1f}% fewer, scanned-body HLO)")
        print(f"  prefill (256-token prompt) bytes "
              f"{r['prefill_bytes_skipless'] / 1e6:.1f} MB -> "
              f"{r['prefill_bytes_merged'] / 1e6:.1f} MB "
              f"({100 * r['prefill_bytes_saved_frac']:.1f}% fewer, "
              f"stream-as-query fast path)")
        print(f"  full-depth weight stream (paper §3, bf16): "
              f"{r['model_weight_bytes_with_qp'] / 1e9:.2f} GB -> "
              f"{r['model_weight_bytes_without_qp'] / 1e9:.2f} GB/token "
              f"({100 * r['model_bytes_saved_frac']:.1f}% fewer)")
        print(f"  measured (reduced shapes, CPU): "
              f"{r['tok_s_skipless']:.1f} tok/s generic -> "
              f"{r['tok_s_merged']:.1f} tok/s merged fast path; "
              f"greedy streams identical: {r['tokens_equal']}")
    print("OK")


if __name__ == "__main__":
    main()
