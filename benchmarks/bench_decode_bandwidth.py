"""Batch-1 decode bandwidth model (paper §3's speedup, extended).

ms/token lower bound when weight streaming saturates HBM (v5e: 819 GB/s),
with and without QP removal, for every assigned architecture — plus the KV
cache read traffic at the assigned decode contexts (beyond the paper, which
models weights only)."""
from __future__ import annotations

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import (active_weights_per_token, decode_ms_per_token,
                        weight_table)


def kv_bytes_per_token(cfg, context: int, bytes_per=2) -> int:
    """KV cache bytes read per decoded token at a given context."""
    if not cfg.has_attention:
        # SSD state read instead: (H, P, N) fp32 per layer
        return cfg.n_layers * cfg.ssm_n_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    eff = min(context, cfg.sliding_window) if cfg.sliding_window else context
    kv = cfg.n_layers * 2 * eff * cfg.kv_dim * bytes_per
    if cfg.ssm_state:  # hybrid: both
        kv += cfg.n_layers * cfg.ssm_n_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    return kv


def run(context: int = 32_768):
    rows = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if cfg.is_encoder:
            continue  # no autoregressive decode
        t = weight_table(cfg)
        act_w = active_weights_per_token(cfg, with_qp=True)
        act_wo = active_weights_per_token(cfg, with_qp=False)
        kvb = kv_bytes_per_token(cfg, context)
        ms_with = decode_ms_per_token(act_w) + kvb / 819e9 * 1e3
        ms_wo = decode_ms_per_token(act_wo) + kvb / 819e9 * 1e3
        rows.append(dict(
            arch=arch, weights_ms=decode_ms_per_token(act_w),
            kv_ms=kvb / 819e9 * 1e3,
            ms_with=ms_with, ms_without=ms_wo,
            speedup_weights=t["speedup"],
            speedup_e2e=ms_with / ms_wo if ms_wo else 1.0))
    return rows


def main():
    rows = run()
    print(f"{'arch':26s} {'W ms/tok':>9s} {'KV ms/tok':>10s} "
          f"{'paper speedup':>14s} {'e2e speedup@32k':>16s}")
    for r in rows:
        print(f"{r['arch']:26s} {r['weights_ms']:>9.2f} {r['kv_ms']:>10.3f} "
              f"{r['speedup_weights']:>14.3f} {r['speedup_e2e']:>16.3f}")
    print("(bf16 weights, fp32 SSM state, v5e 819 GB/s; batch 1, 1 chip)")


if __name__ == "__main__":
    main()
