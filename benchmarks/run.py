"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run

Prints a ``name,us_per_call,derived`` CSV summary after the tables.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import (bench_decode_bandwidth, bench_decode_merged,
                            bench_equivalence, bench_kernels, bench_numerics,
                            bench_paged_serving, bench_roofline,
                            bench_weight_table)

    suites = [
        ("weight_table[paper_s3]", bench_weight_table),
        ("equivalence[paper_s4]", bench_equivalence),
        ("decode_bandwidth[paper_s3_ext]", bench_decode_bandwidth),
        ("decode_merged[fastpath]", bench_decode_merged),
        ("paged_serving[subsystem]", bench_paged_serving),
        ("numerics[merged_runtime]", bench_numerics),
        ("kernels", bench_kernels),
        ("roofline[dryrun]", bench_roofline),
    ]
    csv = ["name,us_per_call,derived"]
    for name, mod in suites:
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.perf_counter()
        mod.main()
        us = (time.perf_counter() - t0) * 1e6
        derived = ""
        try:
            rows = mod.run()
            if name.startswith("weight_table"):
                m = next(r for r in rows if r["arch"] == "mistral-7b")
                derived = f"mistral_speedup={m['speedup']:.3f}"
            elif name.startswith("equivalence"):
                derived = f"max_rel_err={max(r['rel_err'] for r in rows):.2e}"
            elif name.startswith("decode_bandwidth"):
                m = next(r for r in rows if r["arch"] == "qwen2.5-32b")
                derived = f"qwen_e2e_speedup={m['speedup_e2e']:.3f}"
            elif name.startswith("decode_merged"):
                m = next(r for r in rows if r["arch"] == "mistral-7b")
                derived = (f"mistral_bytes_saved={m['bytes_saved_frac']:.3f}"
                           f";prefill_bytes_saved="
                           f"{m['prefill_bytes_saved_frac']:.3f}")
            elif name.startswith("paged_serving"):
                # run() -> (serve rows, prefill rows, merged-prefill rows,
                #           windowed serve rows, instrumented obs doc,
                #           quantized-pool doc)
                (rows, prefill, merged_prefill, rows_w, obs_doc,
                 quant_doc) = rows
                # persist the perf-trajectory payloads
                obs_path = bench_paged_serving.write_obs_doc(obs_doc)
                bench_paged_serving.write_quant_doc(quant_doc)
                dn = next(r for r in rows if r["weights"] == "merged_qp"
                          and r["cache"] == "dense")
                pg = next(r for r in rows if r["weights"] == "merged_qp"
                          and r["cache"] == "paged")
                pf = prefill[-1]
                saved = 1.0 - pf["paged_bytes"] / pf["paged_legacy_bytes"]
                mp = merged_prefill[-1]
                msaved = 1.0 - mp["paged_merged"] / mp["paged_generic"]
                wd = next(r for r in rows_w if r["weights"] == "merged_qp"
                          and r["cache"] == "dense")
                wp = next(r for r in rows_w if r["weights"] == "merged_qp"
                          and r["cache"] == "paged")
                h = obs_doc["headline"]
                qh = quant_doc["equal_hbm"]
                qerr = max(s["logit_rel_err"]
                           for s in quant_doc["numerics"].values())
                derived = (f"streams_paged_vs_dense="
                           f"{pg['peak_streams']}v{dn['peak_streams']}"
                           f";prefill_bytes_saved={saved:.3f}"
                           f";merged_prefill_bytes_saved={msaved:.3f}"
                           f";windowed_streams="
                           f"{wp['peak_streams']}v{wd['peak_streams']}"
                           f";windowed_page_hwm={wp['page_hwm']}"
                           f"of{wp['ring_bound']}"
                           f";q8_stream_gain={qh['stream_gain']:.2f}"
                           f";q8_max_rel_err={qerr:.3f}"
                           f";obs_ttft_p99_ms={h['ttft_p99_ms']:.1f}"
                           f";obs_json={obs_path}")
            elif name.startswith("numerics"):
                o = next(r for r in rows if r["init"] == "orthogonal"
                         and r["dtype"] == "float32")
                derived = f"ortho_fp32_rel={o['rel_err']:.2e}"
            elif name == "kernels":
                derived = f"max_err={max(r['err'] for r in rows):.2e}"
            elif name.startswith("roofline"):
                derived = f"cells={len(rows)}"
        except Exception as e:  # derived metrics are best-effort
            derived = f"derived_error={type(e).__name__}"
        csv.append(f"{name},{us:.0f},{derived}")

    print("\n" + "\n".join(csv))


if __name__ == "__main__":
    main()
